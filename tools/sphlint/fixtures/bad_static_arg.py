"""BAD: an unfrozen, unhashable config riding jit as a static arg.

The PR 7/8 class of bug: the sweep/serve compile caches key on the
config — an unfrozen dataclass with list fields either crashes at
trace time ("unhashable type") or silently splits the cache.
"""
import dataclasses


@dataclasses.dataclass
class SweepConfig:
    name: str = "sweep"
    dts: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class MemberPolicy:
    tags: dict = dataclasses.field(default_factory=dict)
