"""GOOD: frozen config, hashable (tuple) leaves."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    name: str = "sweep"
    dts: tuple = ()


@dataclasses.dataclass(frozen=True)
class MemberPolicy:
    tags: tuple = ()
