"""GOOD: host work stays on host; the scan body stays on device."""
import jax
import jax.numpy as jnp


def run(carry0, steps: int, h: float):
    r_cell = float(h) * 2.0  # static config math, outside the trace

    def body(count, _):
        return count + 1, count.astype(jnp.float32)

    carry, ys = jax.lax.scan(body, carry0, None, length=steps)
    return carry, ys, r_cell


def report(carry):
    # host read AFTER the scan returns — one sync for the whole run
    return float(jax.device_get(carry))
