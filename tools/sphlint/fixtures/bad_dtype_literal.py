"""BAD: scattered half-precision literals (pre-PR-9 rcll.py style)."""
import jax.numpy as jnp


def init_rel(x, dtype=jnp.float16):
    """Storage dtype decided ad hoc instead of via PrecisionPolicy."""
    return x.astype(dtype)


def build_records(encode):
    return encode(records="fp16")


def pick_layout():
    records_dtype = "bf16"
    return records_dtype
