"""BAD: the PR 6 silent fp16->fp32 fallback, minimized.

Precision silently degrades in two classic shapes: a conditional
return of a different precision string, and an except handler that
swaps the config's records field — neither logs, raises, nor records
a GuardEvent.
"""
import dataclasses


def resolve_records(cfg):
    if max(cfg.ncells) >= 2048:
        return "fp32"
    return cfg.records


def build(cfg, compile_half, compile_full):
    try:
        return compile_half(cfg)
    except Exception:
        cfg = dataclasses.replace(cfg, records="fp32")
        return compile_full(cfg)
