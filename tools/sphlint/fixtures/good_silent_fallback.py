"""GOOD: the fallback degrades LOUDLY (log / GuardEvent / raise)."""
import dataclasses
import logging

log = logging.getLogger(__name__)


def resolve_records(cfg):
    if max(cfg.ncells) >= 2048:
        log.warning("grid exceeds half-record anchor range; fp32 records")
        return "fp32"
    return cfg.records


def build(cfg, compile_half, compile_full):
    try:
        return compile_half(cfg)
    except Exception:
        log.warning("half-record build failed; falling back to fp32")
        cfg = dataclasses.replace(cfg, records="fp32")
        return compile_full(cfg)
