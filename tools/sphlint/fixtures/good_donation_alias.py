"""GOOD: the PR 3 fix — the live argument gets its own buffer."""
from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, donate_argnums=(0,))
def advance(cell_xy, binning_xy):
    return cell_xy + 1, binning_xy


def run(st):
    return advance(st.rc.cell_xy, jnp.copy(st.rc.cell_xy))
