"""sphlint Layer B: compile the production programs, audit the jaxprs.

What the AST layer cannot see, the jaxpr can: this module builds the
persistent step and rebuild programs for registered cases across the
force backends and checks the invariants the mixed-precision design
actually rests on:

* **fp16 confinement** — every equation producing an fp16/bf16 value is
  a STRUCTURAL op (gather/bitcast/convert/reshape/…): half precision is
  a storage format here, never an arithmetic one. An `add` or
  `dot_general` with an f16 output means a computation silently dropped
  to half precision (the accumulate-in-fp32 rule broke).
* **no host callbacks** — no debug/io callback primitives anywhere in
  the step program (the PR 6 in-scan overflow-callback incident).
* **donation** — ``run_persistent``'s declared ``donate_argnums``
  buffers actually donate: compiling must not emit "donated buffer was
  not usable" warnings.
* **no carry self-aliasing** — no two leaves of the donated carry share
  a device buffer (the PR 3 ``st.rc.cell_xy``/``binning.cell_xy``
  incident class, checked by pointer this time).

The report includes a per-program dtype census (equation-output counts
by dtype) so precision drift between PRs is visible as a diff.
"""
from __future__ import annotations

import collections
import json
import sys
import warnings
from pathlib import Path

#: Primitives allowed to OUTPUT an fp16/bf16 value: data movement,
#: layout, and format conversion — no arithmetic. Container primitives
#: (scan/cond/pjit/…) are allowed because their inner equations are
#: audited individually by the recursive walk.
STRUCTURAL_F16_PRIMS = frozenset({
    "gather", "bitcast_convert_type", "convert_element_type",
    "concatenate", "reshape", "slice", "dynamic_slice",
    "dynamic_update_slice", "broadcast_in_dim", "transpose", "squeeze",
    "expand_dims", "pad", "rev", "select_n", "scatter", "copy",
    "stop_gradient", "device_put", "iota",
    # Pallas ref load/store (pl.load / ref[...] / pl.store) — memory
    # movement. `addupdate` is deliberately NOT here: an f16 in-ref
    # accumulate would break the fp32-accumulator rule.
    "get", "swap", "masked_load", "masked_store",
    # containers — audited by recursing into their sub-jaxprs
    "scan", "while", "cond", "pjit", "closed_call", "core_call",
    "custom_jvp_call", "custom_vjp_call", "remat", "remat2",
    "checkpoint", "pallas_call", "custom_jvp_call_jaxpr",
})

CALLBACK_PRIMS = ("callback", "debug_print", "outside_call", "infeed",
                  "outfeed")

HALF_DTYPES = ("float16", "bfloat16")


# --------------------------------------------------------------------------
# jaxpr walking
# --------------------------------------------------------------------------
def _sub_jaxprs(value):
    """Yield every Jaxpr nested in an eqn param value."""
    import jax

    core = jax.extend.core if hasattr(jax, "extend") else jax.core
    ClosedJaxpr = core.ClosedJaxpr
    Jaxpr = core.Jaxpr
    if isinstance(value, ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, Jaxpr):
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _sub_jaxprs(v)


def iter_eqns(jaxpr, depth=0):
    """All equations of ``jaxpr`` and every nested sub-jaxpr.

    Yields (eqn, depth); depth > 0 means inside at least one container
    primitive (scan body, cond branch, pjit call, pallas kernel, …).
    """
    for eqn in jaxpr.eqns:
        yield eqn, depth
        for value in eqn.params.values():
            for sub in _sub_jaxprs(value):
                yield from iter_eqns(sub, depth + 1)


def _out_dtypes(eqn):
    out = []
    for var in eqn.outvars:
        aval = getattr(var, "aval", None)
        dt = getattr(aval, "dtype", None)
        if dt is not None:
            out.append(str(dt))
    return out


def audit_jaxpr(closed_jaxpr, program: str) -> dict:
    """Audit one program: returns census + violation lists."""
    census: collections.Counter = collections.Counter()
    f16_viol: list[str] = []
    callback_viol: list[str] = []
    for eqn, depth in iter_eqns(closed_jaxpr.jaxpr):
        prim = eqn.primitive.name
        dtypes = _out_dtypes(eqn)
        for dt in dtypes:
            census[dt] += 1
        if any(dt in HALF_DTYPES for dt in dtypes) and \
                prim not in STRUCTURAL_F16_PRIMS:
            f16_viol.append(
                f"{program}: `{prim}` outputs {dtypes} at depth {depth} "
                "— arithmetic in half precision"
            )
        if any(tag in prim for tag in CALLBACK_PRIMS):
            callback_viol.append(
                f"{program}: host-callback primitive `{prim}` at "
                f"depth {depth}"
            )
    return {
        "program": program,
        "census": dict(sorted(census.items())),
        "f16_violations": f16_viol,
        "callback_violations": callback_viol,
    }


# --------------------------------------------------------------------------
# program construction
# --------------------------------------------------------------------------
def _build(case_name: str, backend: str, n: int):
    from repro.core import cases as cases_lib

    ds = cases_lib.resolve_ds(case_name, n)
    case = cases_lib.build_case(case_name, ds=ds, backend=backend)
    return case.build()


def _audit_case(case_name: str, backend: str, n: int, nsteps: int = 4):
    """Audit step + rebuild programs for one (case, backend) pair."""
    import jax

    from repro.core import solver

    cfg, state = _build(case_name, backend, n)
    carry = solver.init_persistent(cfg, state)

    results = []
    label = f"{case_name}/{backend}"

    step_jaxpr = jax.make_jaxpr(
        lambda c: solver.run_persistent(cfg, c, nsteps)
    )(carry)
    results.append(audit_jaxpr(step_jaxpr, f"{label}/step"))

    rebuild_jaxpr = jax.make_jaxpr(
        lambda c: solver._rebuild(cfg, c)
    )(carry)
    results.append(audit_jaxpr(rebuild_jaxpr, f"{label}/rebuild"))

    donation = _audit_donation(cfg, carry, nsteps, label)
    alias = _audit_carry_aliasing(carry, label)
    return results, donation, alias


def _audit_donation(cfg, carry, nsteps: int, label: str) -> dict:
    """Compile run_persistent and catch 'donated buffer unused' warnings."""
    from repro.core import solver

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        solver.run_persistent.lower(cfg, carry, nsteps).compile()
    msgs = [str(w.message) for w in caught
            if "donat" in str(w.message).lower()]
    return {
        "program": f"{label}/step",
        "donation_warnings": msgs,
    }


def _audit_carry_aliasing(carry, label: str) -> dict:
    """No two leaves of the donated carry may share a device buffer."""
    import jax

    leaves, _ = jax.tree_util.tree_flatten_with_path(carry)
    by_ptr: dict[int, list[str]] = {}
    for path, leaf in leaves:
        try:
            ptr = leaf.unsafe_buffer_pointer()
        except Exception:
            continue  # committed-elsewhere / non-array leaf
        by_ptr.setdefault(ptr, []).append(jax.tree_util.keystr(path))
    aliases = [paths for paths in by_ptr.values() if len(paths) > 1]
    return {
        "program": f"{label}/carry",
        "aliased_leaves": aliases,
    }


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------
def run_trace_audit(backends, cases, n=300, report_path: Path | None = None,
                    verbose: bool = False) -> int:
    print(f"sphlint trace: cases={cases} backends={backends} n~{n}",
          flush=True)
    report = {"cases": cases, "backends": backends, "n": n, "programs": [],
              "donation": [], "aliasing": []}
    failures: list[str] = []
    for case_name in cases:
        for backend in backends:
            label = f"{case_name}/{backend}"
            try:
                results, donation, alias = _audit_case(
                    case_name, backend, n)
            except Exception as e:  # surface, keep auditing the rest
                failures.append(f"{label}: audit crashed: {e!r}")
                print(f"  {label}: CRASH {e!r}", flush=True)
                continue
            report["programs"].extend(results)
            report["donation"].append(donation)
            report["aliasing"].append(alias)
            bad = []
            for r in results:
                bad += r["f16_violations"] + r["callback_violations"]
            bad += [f"{donation['program']}: {m}"
                    for m in donation["donation_warnings"]]
            bad += [f"{alias['program']}: leaves share one buffer: {p}"
                    for p in alias["aliased_leaves"]]
            failures.extend(bad)
            status = "FAIL" if bad else "ok"
            print(f"  {label}: {status} "
                  f"({len(results)} programs audited)", flush=True)
            if verbose:
                for r in results:
                    print(f"    {r['program']} dtype census: "
                          f"{r['census']}")
    if report_path is not None:
        report["failures"] = failures
        report_path.write_text(json.dumps(report, indent=2) + "\n")
        print(f"sphlint trace: report -> {report_path}")
    if failures:
        print(f"sphlint trace: {len(failures)} violation(s):",
              file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("sphlint trace: all invariants hold "
          f"({len(report['programs'])} programs)")
    return 0
