"""sphlint Layer A engine: AST visitor framework, pragmas, severities.

Deliberately imports NOTHING heavier than the stdlib (no jax, no numpy):
``sphlint check`` must run in well under 5 seconds so it can gate CI and
pre-commit without anyone routing around it.

Concepts
--------
* A :class:`Rule` inspects one :class:`FileContext` (parsed module +
  pragma map + shared traced-reachability analysis) and yields
  :class:`Finding` rows.
* Inline pragmas suppress findings at source level::

      x = jnp.float16  # sphlint: disable=dtype-literal

  The pragma applies to its own line, or — written on a line of its own
  — to the line immediately below. A file-level pragma in the first ten
  lines (``# sphlint: disable-file=rule-a,rule-b``) suppresses a rule
  for the whole file.
* Findings that are real but triaged ride the committed baseline
  (``baseline.py``) instead of pragmas — see the README workflow.

The shared :class:`TraceAnalysis` computes, per module, which local
functions are reachable from traced contexts (``lax.scan``/``lax.map``
bodies, ``jax.jit``-decorated functions) and which are reachable from
``jax.vmap`` — the substrate of the ``host-sync-in-scan`` and
``cond-under-vmap`` rules.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import re
import sys
from pathlib import Path

PRAGMA_RE = re.compile(r"#\s*sphlint:\s*disable=([\w\-,\s]+)")
PRAGMA_FILE_RE = re.compile(r"#\s*sphlint:\s*disable-file=([\w\-,\s]+)")

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint finding. ``key`` (rule, path, line, message) is the
    identity used for baseline matching."""

    rule: str
    path: str  # posix-relative to the invocation cwd
    line: int
    col: int
    message: str
    severity: str = "error"

    @property
    def key(self) -> tuple:
        return (self.rule, self.path, self.line, self.message)

    def to_json(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "severity": self.severity,
        }

    @classmethod
    def from_json(cls, d: dict) -> "Finding":
        return cls(
            rule=d["rule"], path=d["path"], line=int(d["line"]),
            col=int(d.get("col", 0)), message=d["message"],
            severity=d.get("severity", "error"),
        )

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"[{self.rule}] {self.severity}: {self.message}")


# --------------------------------------------------------------------------
# Traced-reachability analysis (shared by host-sync-in-scan /
# cond-under-vmap)
# --------------------------------------------------------------------------
#: Callables whose FUNCTION argument becomes a traced body. Matched on
#: the dotted tail of the call target, so ``jax.lax.scan``, ``lax.scan``
#: and a bare ``scan`` (from-import) all hit.
TRACING_CALLS = {
    "scan": 0, "map": 0, "while_loop": (0, 1), "fori_loop": 2,
    "cond": (1, 2, 3), "switch": None,  # switch: every arg from 1 on
    "vmap": 0, "pmap": 0, "jit": 0, "checkpoint": 0, "remat": 0,
    "custom_vjp": 0, "custom_jvp": 0, "grad": 0, "value_and_grad": 0,
    "shard_map": 0,
}
VMAP_CALLS = ("vmap", "pmap")
JIT_DECORATORS = ("jit",)


def dotted_name(node: ast.AST) -> str:
    """'jax.lax.scan' for Attribute chains, 'scan' for Names, '' else."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def call_tail(node: ast.Call) -> str:
    """Last component of the call target's dotted name."""
    name = dotted_name(node.func)
    return name.rsplit(".", 1)[-1] if name else ""


def _is_jax_namespace(name: str) -> bool:
    """True when a dotted call target plausibly lives in jax (guards the
    bare-name false positives: a local function called ``map`` is not
    ``lax.map``)."""
    head = name.split(".", 1)[0]
    return head in ("jax", "lax", "jnp", "functools", "partial") or \
        "." not in name


class _FuncInfo:
    __slots__ = ("node", "name", "calls", "parent")

    def __init__(self, node, parent):
        self.node = node
        self.name = getattr(node, "name", "<lambda>")
        self.parent = parent  # enclosing _FuncInfo or None
        self.calls: set[str] = set()  # bare names this function calls


class TraceAnalysis:
    """Per-module reachability: which functions run under trace.

    Roots:
      * functions decorated with ``@jax.jit`` / ``@partial(jax.jit, …)``;
      * named functions or lambdas passed to tracing combinators
        (``lax.scan``, ``lax.cond``, ``jax.vmap``, …);
      * nested defs inside any traced function.

    Reachability then closes over same-module calls by bare name. The
    vmap closure is computed separately (roots = ``jax.vmap``/``pmap``
    arguments only) for the ``cond-under-vmap`` rule.
    """

    def __init__(self, tree: ast.Module):
        self.funcs: dict[int, _FuncInfo] = {}  # id(node) -> info
        self.by_name: dict[str, list[_FuncInfo]] = {}
        self.traced_roots: set[int] = set()
        self.vmap_roots: set[int] = set()
        self.root_reason: dict[int, str] = {}
        self._collect(tree)
        self.traced: set[int] = self._closure(self.traced_roots)
        self.vmapped: set[int] = self._closure(self.vmap_roots)

    # -- collection --------------------------------------------------
    def _collect(self, tree):
        stack: list[_FuncInfo] = []
        analysis = self

        class V(ast.NodeVisitor):
            def _enter(self, node):
                info = _FuncInfo(node, stack[-1] if stack else None)
                analysis.funcs[id(node)] = info
                analysis.by_name.setdefault(info.name, []).append(info)
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    for dec in node.decorator_list:
                        if analysis._is_jit_decorator(dec):
                            analysis._root(node, "decorated with jax.jit")
                stack.append(info)
                self.generic_visit(node)
                stack.pop()

            visit_FunctionDef = _enter
            visit_AsyncFunctionDef = _enter
            visit_Lambda = _enter

            def visit_Call(self, node):
                analysis._note_tracing_call(node, stack)
                if stack:
                    tail = call_tail(node)
                    if tail:
                        stack[-1].calls.add(tail)
                self.generic_visit(node)

        V().visit(tree)

    @staticmethod
    def _is_jit_decorator(dec: ast.AST) -> bool:
        name = dotted_name(dec)
        if name.rsplit(".", 1)[-1] in JIT_DECORATORS:
            return True
        if isinstance(dec, ast.Call):
            tail = call_tail(dec)
            if tail in JIT_DECORATORS:
                return True
            if tail == "partial" and dec.args:
                first = dotted_name(dec.args[0])
                if first.rsplit(".", 1)[-1] in JIT_DECORATORS:
                    return True
        return False

    def _root(self, node, reason, vmap=False):
        self.traced_roots.add(id(node))
        self.root_reason.setdefault(id(node), reason)
        if vmap:
            self.vmap_roots.add(id(node))

    def _mark_arg(self, arg, reason, vmap):
        """Mark a function-valued call argument as a traced root."""
        if isinstance(arg, ast.Lambda):
            self._root(arg, reason, vmap)
        elif isinstance(arg, (ast.Name, ast.Attribute)):
            name = dotted_name(arg).rsplit(".", 1)[-1]
            for info in self.by_name.get(name, []):
                self._root(info.node, reason, vmap)

    def _note_tracing_call(self, node: ast.Call, stack):
        tail = call_tail(node)
        if tail not in TRACING_CALLS:
            return
        name = dotted_name(node.func)
        if not _is_jax_namespace(name):
            return
        # jax.tree.map / tree_util.tree_map apply f OUTSIDE the trace —
        # they are pytree plumbing, not tracing combinators.
        if "tree" in name:
            return
        spec = TRACING_CALLS[tail]
        vmap = tail in VMAP_CALLS
        reason = f"passed to {dotted_name(node.func)}"
        if tail == "switch":
            positions = range(1, len(node.args))
        elif isinstance(spec, tuple):
            positions = spec
        else:
            positions = (spec,)
        for pos in positions:
            if pos < len(node.args):
                self._mark_arg(node.args[pos], reason, vmap)
        for kw in node.keywords:
            if kw.arg in ("f", "fun", "body", "body_fun", "cond_fun"):
                self._mark_arg(kw.value, reason, vmap)

    # -- closure -----------------------------------------------------
    def _closure(self, roots: set[int]) -> set[int]:
        reached = set(roots)
        # nested defs inside a traced function are traced
        changed = True
        while changed:
            changed = False
            for fid, info in self.funcs.items():
                if fid in reached:
                    continue
                parent = info.parent
                if parent is not None and id(parent.node) in reached:
                    reached.add(fid)
                    self.root_reason.setdefault(
                        fid, f"nested in traced {parent.name!r}")
                    changed = True
            # same-module calls by bare name
            for fid in list(reached):
                for callee in self.funcs[fid].calls:
                    for info in self.by_name.get(callee, []):
                        if id(info.node) not in reached:
                            reached.add(id(info.node))
                            self.root_reason.setdefault(
                                id(info.node),
                                f"called from traced "
                                f"{self.funcs[fid].name!r}")
                            changed = True
        return reached

    def reason(self, node) -> str:
        return self.root_reason.get(id(node), "traced context")


# --------------------------------------------------------------------------
# File context + rule protocol
# --------------------------------------------------------------------------
class FileContext:
    """Everything a rule needs about one source file."""

    def __init__(self, path: Path, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        self.disabled_lines: dict[int, set[str]] = {}
        self.disabled_file: set[str] = set()
        self._scan_pragmas()
        self._trace: TraceAnalysis | None = None

    @property
    def trace(self) -> TraceAnalysis:
        if self._trace is None:
            self._trace = TraceAnalysis(self.tree)
        return self._trace

    def _scan_pragmas(self):
        for i, line in enumerate(self.lines, start=1):
            m = PRAGMA_FILE_RE.search(line)
            if m and i <= 10:
                self.disabled_file |= {
                    r.strip() for r in m.group(1).split(",") if r.strip()
                }
                continue
            m = PRAGMA_RE.search(line)
            if not m:
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            self.disabled_lines.setdefault(i, set()).update(rules)
            # a standalone pragma comment guards the NEXT line
            if line.split("#", 1)[0].strip() == "":
                self.disabled_lines.setdefault(i + 1, set()).update(rules)

    def suppressed(self, rule: str, line: int) -> bool:
        if rule in self.disabled_file or "all" in self.disabled_file:
            return True
        rules = self.disabled_lines.get(line, ())
        return rule in rules or "all" in rules


class Rule:
    """Base rule: subclasses set ``name``/``severity`` and implement
    ``check``."""

    name = "abstract"
    severity = "error"
    #: fnmatch patterns (against the posix relpath) where the rule does
    #: not apply at all — the sanctioned-site mechanism.
    allow_paths: tuple = ()

    def applies(self, rel: str) -> bool:
        return not any(fnmatch.fnmatch(rel, p) for p in self.allow_paths)

    def check(self, ctx: FileContext):
        raise NotImplementedError

    def finding(self, ctx: FileContext, node: ast.AST, message: str
                ) -> Finding:
        return Finding(
            rule=self.name, path=ctx.rel,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0),
            message=message, severity=self.severity,
        )


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------
def collect_files(paths: list[str]) -> list[Path]:
    out: list[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            # fixture corpora (known-bad incident replays) are linted
            # only when passed explicitly, never via directory sweep
            out.extend(sorted(
                f for f in path.rglob("*.py")
                if "fixtures" not in f.parts
            ))
        elif path.suffix == ".py":
            out.append(path)
    return out


def _relpath(path: Path) -> str:
    try:
        return path.resolve().relative_to(Path.cwd().resolve()).as_posix()
    except ValueError:
        return path.as_posix()


def lint_paths(paths: list[str], rules=None) -> list[Finding]:
    """Run ``rules`` (default: the full registry) over ``paths``.

    Returns pragma-filtered findings sorted by (path, line, rule).
    Syntax errors surface as findings of the pseudo-rule ``parse-error``
    rather than crashing the whole run.
    """
    if rules is None:
        from tools.sphlint.rules import default_rules
        rules = default_rules()
    findings: list[Finding] = []
    for path in collect_files(paths):
        rel = _relpath(path)
        try:
            source = path.read_text()
            ctx = FileContext(path, rel, source)
        except (SyntaxError, UnicodeDecodeError) as e:
            findings.append(Finding(
                rule="parse-error", path=rel,
                line=getattr(e, "lineno", 1) or 1, col=0,
                message=f"could not parse: {e.msg if hasattr(e, 'msg') else e}",
            ))
            continue
        for rule in rules:
            if not rule.applies(rel):
                continue
            for f in rule.check(ctx):
                if not ctx.suppressed(f.rule, f.line):
                    findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    return findings


def render_findings(findings: list[Finding], stream=None) -> None:
    stream = stream or sys.stdout
    for f in findings:
        print(f.render(), file=stream)
