"""sphlint Layer A rules — one per incident this repo has paid for.

Rule → incident map (the README carries the long-form table):

  dtype-literal          PR 3/6: precision decisions scattered as raw
                         ``jnp.float16`` / ``"fp16"`` literals instead
                         of flowing through ``PrecisionPolicy``.
  host-sync-in-scan      PR 6: the in-scan ``jax.debug.callback``
                         overflow check — a device sync point on every
                         step — retired by the health-word redesign.
  cond-under-vmap        PR 7: ``lax.cond`` under ``vmap`` executes
                         BOTH branches (the batched rebuild-cadence
                         lesson) — a silent 2x cost or a hidden
                         all-lanes rebuild.
  static-arg-hashability PR 7/8: configs ride ``jax.jit`` as static
                         args and key the serve/sweep compile caches —
                         an unhashable or unfrozen config either
                         crashes at trace time or silently splits the
                         cache.
  donation-alias         PR 3/8: ``st.rc.cell_xy`` aliased
                         ``binning.cell_xy`` inside a donated carry;
                         XLA refuses to donate one buffer through two
                         arguments (prewarm donated-buffer race).
  silent-fallback        PR 6: the build-time fp16→fp32 record fallback
                         that had to be retrofitted with logging —
                         precision/backend changes must be loud
                         (GuardEvent or log), never silent.
"""
from __future__ import annotations

import ast
import re

from tools.sphlint.engine import (
    FileContext, Rule, call_tail, dotted_name,
)

HALF_DTYPE_ATTRS = ("float16", "bfloat16", "half")
HALF_DTYPE_STRINGS = ("fp16", "bf16", "float16", "bfloat16")
PRECISION_STRINGS = ("fp16", "bf16", "fp32", "fp64")
LOG_CALL_TAILS = (
    "warning", "warn", "error", "info", "debug", "exception", "critical",
    "log",
)
EVENT_NAMES = ("GuardEvent",)


def _contains_logging(node: ast.AST) -> bool:
    """True when the subtree logs, raises, or records a GuardEvent —
    i.e. the change it guards is LOUD."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Raise):
            return True
        if isinstance(sub, ast.Call):
            name = dotted_name(sub.func)
            tail = name.rsplit(".", 1)[-1]
            if tail in LOG_CALL_TAILS and ("." in name or tail == "log"):
                return True
            if tail in EVENT_NAMES:
                return True
    return False


# --------------------------------------------------------------------------
class DtypeLiteralRule(Rule):
    """Half-precision dtype literals outside the precision module.

    Flags ``*.float16`` / ``*.bfloat16`` attribute access and raw
    ``"fp16"``-family strings used as dtype/records arguments or
    assigned to dtype-ish names. Precision decisions must flow through
    ``core/precision.py`` (``PrecisionPolicy`` / the storage-dtype
    constants); sanctioned encode/decode sites carry inline pragmas.
    """

    name = "dtype-literal"
    severity = "error"
    allow_paths = ("*core/precision.py",)

    DTYPE_KWARGS = re.compile(
        r"(dtype|records|coords|nnps|storage|compute)", re.IGNORECASE
    )

    def check(self, ctx: FileContext):
        flagged: set[int] = set()  # id(node) already reported

        def report(node, what):
            if id(node) in flagged:
                return None
            flagged.add(id(node))
            return self.finding(
                ctx, node,
                f"{what} — route precision through core/precision.py "
                "(PrecisionPolicy or its storage-dtype constants), or "
                "pragma a sanctioned encode/decode site",
            )

        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Attribute) and \
                    node.attr in HALF_DTYPE_ATTRS:
                base = dotted_name(node.value)
                if base.rsplit(".", 1)[-1] in (
                        "jnp", "np", "numpy", "jax", "torch"):
                    f = report(node, f"half-precision dtype literal "
                               f"`{base}.{node.attr}`")
                    if f:
                        yield f
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg and self.DTYPE_KWARGS.search(kw.arg) and \
                            isinstance(kw.value, ast.Constant) and \
                            kw.value.value in HALF_DTYPE_STRINGS:
                        f = report(
                            kw.value,
                            f"raw dtype string {kw.value.value!r} passed "
                            f"as `{kw.arg}=`")
                        if f:
                            yield f
            elif isinstance(node, (ast.Assign, ast.AnnAssign)):
                value = node.value
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                if isinstance(value, ast.Constant) and \
                        value.value in HALF_DTYPE_STRINGS:
                    for t in targets:
                        tn = dotted_name(t)
                        if tn and self.DTYPE_KWARGS.search(tn):
                            f = report(
                                value,
                                f"raw dtype string {value.value!r} "
                                f"assigned to `{tn}`")
                            if f:
                                yield f


# --------------------------------------------------------------------------
class HostSyncInScanRule(Rule):
    """Host-sync operations on traced values inside scan/vmap/jit bodies.

    ``float()`` / ``int()`` / ``bool()`` / ``.item()`` / ``np.asarray``
    force a device→host transfer (a sync point per step when scanned);
    ``jax.debug.callback`` / ``io_callback`` / ``debug.print`` insert
    host callbacks into the compiled program. Static uses (shapes,
    ``len``, ``finfo``, literals) are exempt.
    """

    name = "host-sync-in-scan"
    severity = "error"

    CAST_BUILTINS = ("float", "int", "bool", "complex")
    NP_SYNC = ("asarray", "array")
    CALLBACKS = ("callback", "pure_callback", "io_callback", "debug_print",
                 "device_get")
    #: parameter annotations that mark a TRACED value; anything else
    #: (float, tuple, Domain, Scheme, …) is host-side configuration.
    ARRAYISH = re.compile(r"(Array|ndarray|Tensor|ArrayLike)")

    @classmethod
    def _static_arg(cls, arg: ast.AST) -> bool:
        """Heuristically static (host-side) expressions: literals,
        shapes, lengths, finfo/iinfo fields, dataclass config floats."""
        if isinstance(arg, ast.Constant):
            return True
        text = ast.dump(arg)
        for marker in ("attr='shape'", "attr='ndim'", "attr='size'",
                       "id='len'", "id='finfo'", "attr='finfo'",
                       "attr='iinfo'", "id='range'", "attr='dtype'",
                       "attr='itemsize'", "attr='nmant'", "attr='eps'"):
            if marker in text:
                return True
        return False

    def check(self, ctx: FileContext):
        trace = ctx.trace
        seen: set[int] = set()
        for fid, info in trace.funcs.items():
            if fid not in trace.traced:
                continue
            fn = info.node
            reason = trace.reason(fn)
            traced_names = self._traced_names(fn)
            body = fn.body if isinstance(fn.body, list) else [fn.body]
            for stmt in body:
                for node in ast.walk(stmt):
                    if not isinstance(node, ast.Call) or id(node) in seen:
                        continue
                    msg = self._classify(node, traced_names)
                    if msg is None:
                        continue
                    seen.add(id(node))
                    yield self.finding(
                        ctx, node,
                        f"{msg} inside a traced body ({reason}) — a "
                        "host sync/callback per step; compute it on "
                        "device or hoist it out of the scan",
                    )

    # -- traced-value data flow --------------------------------------
    def _traced_names(self, fn) -> set[str]:
        """Names in ``fn`` that (likely) hold traced arrays: non-static
        Array-annotated or unannotated parameters, closed over
        assignments whose RHS references a traced name."""
        a = fn.args
        params = list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
        static = self._static_params(fn)
        traced: set[str] = set()
        for p in params:
            if p.arg in static or p.arg in ("self", "cls"):
                continue
            ann = getattr(p, "annotation", None)
            if ann is not None and not self.ARRAYISH.search(
                    ast.unparse(ann)):
                continue  # float / tuple / Domain / Scheme → host config
            traced.add(p.arg)
        changed = True
        while changed:
            changed = False
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Assign):
                    rhs, targets = sub.value, sub.targets
                elif isinstance(sub, ast.AnnAssign) and sub.value:
                    rhs, targets = sub.value, [sub.target]
                elif isinstance(sub, ast.AugAssign):
                    rhs, targets = sub.value, [sub.target]
                else:
                    continue
                if self._static_arg(rhs) or \
                        not self._refs(rhs, traced):
                    continue
                for t in targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name) and n.id not in traced:
                            traced.add(n.id)
                            changed = True
        return traced

    @staticmethod
    def _refs(expr: ast.AST, names: set[str]) -> bool:
        return any(isinstance(n, ast.Name) and n.id in names
                   for n in ast.walk(expr))

    @staticmethod
    def _static_params(fn) -> set[str]:
        """Parameter names declared static in the jit decorator."""
        out: set[str] = set()
        decs = getattr(fn, "decorator_list", [])
        a = fn.args
        positional = [p.arg for p in list(a.posonlyargs) + list(a.args)]
        for dec in decs:
            if not isinstance(dec, ast.Call):
                continue
            for kw in dec.keywords:
                if kw.arg == "static_argnames":
                    for n in ast.walk(kw.value):
                        if isinstance(n, ast.Constant) and \
                                isinstance(n.value, str):
                            out.add(n.value)
                elif kw.arg == "static_argnums":
                    for n in ast.walk(kw.value):
                        if isinstance(n, ast.Constant) and \
                                isinstance(n.value, int) and \
                                n.value < len(positional):
                            out.add(positional[n.value])
        return out

    def _classify(self, node: ast.Call, traced: set[str]) -> str | None:
        name = dotted_name(node.func)
        tail = call_tail(node)
        if isinstance(node.func, ast.Name) and \
                tail in self.CAST_BUILTINS and node.args:
            arg = node.args[0]
            if not self._static_arg(arg) and self._refs(arg, traced):
                return f"`{tail}()` cast of a traced value"
            return None
        if isinstance(node.func, ast.Attribute) and node.func.attr == "item":
            base = node.func.value
            if self._refs(base, traced) or isinstance(base, ast.Name):
                return "`.item()` host read"
            return None
        head = name.split(".", 1)[0]
        if head in ("np", "numpy") and tail in self.NP_SYNC:
            if node.args and self._refs(node.args[0], traced):
                return f"`{name}` materializes a device value on host"
            return None
        if tail in self.CALLBACKS and (
                "debug" in name or "jax" in name or
                "experimental" in name or tail == "device_get"):
            return f"`{name}` host callback"
        if name in ("jax.debug.print", "debug.print"):
            return f"`{name}` host callback"
        return None


# --------------------------------------------------------------------------
class CondUnderVmapRule(Rule):
    """``lax.cond`` in functions reachable from ``jax.vmap``.

    Under batching, ``cond`` lowers to ``select`` — BOTH branches
    execute for every lane (the PR 7 rebuild-cadence lesson: a single
    lane's rebuild ran the full rebuild for the whole batch). Either
    restructure so the cond sits outside the vmap, or acknowledge the
    both-branches cost with a pragma.
    """

    name = "cond-under-vmap"
    severity = "error"

    def check(self, ctx: FileContext):
        trace = ctx.trace
        for fid, info in trace.funcs.items():
            if fid not in trace.vmapped:
                continue
            for node in ast.walk(info.node):
                if isinstance(node, ast.Call) and \
                        call_tail(node) == "cond" and \
                        "lax" in dotted_name(node.func):
                    yield self.finding(
                        ctx, node,
                        f"`lax.cond` in `{info.name}`, reachable from "
                        f"jax.vmap ({trace.reason(info.node)}): both "
                        "branches execute per lane under batching — "
                        "hoist the decision out of the vmap or pragma "
                        "the accepted cost",
                    )


# --------------------------------------------------------------------------
class StaticArgHashabilityRule(Rule):
    """Config dataclasses must be frozen with hashable leaves.

    Applies to ``@dataclasses.dataclass`` classes whose name marks them
    as config-family (``*Config``/``*Policy``/``*Spec``/``*Scheme``/
    ``*Profile``): they ride ``jax.jit`` as static arguments and key
    the serve/sweep normalized-config caches, so they must be
    ``frozen=True`` and must not carry unhashable (list/dict/set) or
    mutable-default fields.
    """

    name = "static-arg-hashability"
    severity = "error"

    CONFIG_NAME = re.compile(r"(Config|Policy|Spec|Scheme|Profile)$")
    UNHASHABLE_ANNOT = re.compile(
        r"^(typing\.)?(list|List|dict|Dict|set|Set)\b"
    )

    def check(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            dc = self._dataclass_decorator(node)
            if dc is None or not self.CONFIG_NAME.search(node.name):
                continue
            frozen = self._is_frozen(dc)
            if not frozen:
                yield self.finding(
                    ctx, node,
                    f"config dataclass `{node.name}` is not "
                    "frozen=True: static jit args and compile-cache "
                    "keys must be immutable and hashable",
                )
            for stmt in node.body:
                if not isinstance(stmt, ast.AnnAssign) or \
                        not isinstance(stmt.target, ast.Name):
                    continue
                ann = ast.unparse(stmt.annotation)
                if self.UNHASHABLE_ANNOT.match(ann):
                    yield self.finding(
                        ctx, stmt,
                        f"`{node.name}.{stmt.target.id}: {ann}` is an "
                        "unhashable leaf — use a tuple / frozenset / "
                        "frozen sub-dataclass",
                    )
                if isinstance(stmt.value, (ast.List, ast.Dict, ast.Set)):
                    yield self.finding(
                        ctx, stmt,
                        f"`{node.name}.{stmt.target.id}` has a mutable "
                        "default — unhashable and shared across "
                        "instances",
                    )

    @staticmethod
    def _dataclass_decorator(node: ast.ClassDef):
        for dec in node.decorator_list:
            name = dotted_name(dec if not isinstance(dec, ast.Call)
                               else dec.func)
            if name.rsplit(".", 1)[-1] == "dataclass":
                return dec
        return None

    @staticmethod
    def _is_frozen(dec) -> bool:
        if not isinstance(dec, ast.Call):
            return False
        for kw in dec.keywords:
            if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
                return bool(kw.value.value)
        return False


# --------------------------------------------------------------------------
class DonationAliasRule(Rule):
    """The same buffer passed to a donating function twice.

    A function jitted with ``donate_argnums`` invalidates its donated
    arguments; passing one expression both as the donated argument and
    as another argument makes XLA refuse the donation (loud at best) or
    hands the callee an invalidated alias (the PR 3
    ``st.rc.cell_xy``/``binning.cell_xy`` incident, the PR 8 prewarm
    race). The deep structural form of this check (pytree leaves that
    alias across arguments) lives in ``sphlint trace``.
    """

    name = "donation-alias"
    severity = "error"

    def check(self, ctx: FileContext):
        donating = self._donating_functions(ctx.tree)
        if not donating:
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            tail = call_tail(node)
            if tail not in donating:
                continue
            donate = donating[tail]
            exprs = [ast.dump(a) for a in node.args]
            donated = {i for i in donate if i < len(exprs)}
            for i in donated:
                for j, other in enumerate(exprs):
                    if j == i or exprs[i] != other:
                        continue
                    if isinstance(node.args[i], ast.Constant):
                        continue
                    yield self.finding(
                        ctx, node.args[j],
                        f"argument {j} of `{tail}` repeats donated "
                        f"argument {i} (`{ast.unparse(node.args[i])}`): "
                        "the donated buffer would alias a live "
                        "argument — pass a copy (jnp.copy) or "
                        "restructure",
                    )

    @staticmethod
    def _donating_functions(tree) -> dict[str, tuple]:
        """name -> donate_argnums for functions jitted with donation."""
        out: dict[str, tuple] = {}
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for dec in node.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                tail = call_tail(dec)
                target = dec
                if tail == "partial" and dec.args and \
                        dotted_name(dec.args[0]).endswith("jit"):
                    target = dec
                elif tail != "jit":
                    continue
                for kw in target.keywords:
                    if kw.arg == "donate_argnums":
                        nums = DonationAliasRule._const_tuple(kw.value)
                        if nums:
                            out[node.name] = nums
        return out

    @staticmethod
    def _const_tuple(node) -> tuple:
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return (node.value,)
        if isinstance(node, (ast.Tuple, ast.List)):
            vals = []
            for e in node.elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, int):
                    vals.append(e.value)
            return tuple(vals)
        return ()


# --------------------------------------------------------------------------
class SilentFallbackRule(Rule):
    """Precision/backend fallbacks must be loud.

    Flags (a) ``except`` handlers that change a records/backend/dtype
    field and (b) conditional returns of a precision string, when the
    surrounding handler/branch neither logs, raises, nor records a
    GuardEvent. The PR 6 incident: the build-time fp16→fp32 record
    fallback ran silently until the health guard retrofitted the loud
    path; new fallbacks must start loud.
    """

    name = "silent-fallback"
    severity = "error"

    PRECISION_FIELD = re.compile(r"(records|backend|dtype|policy|precision)",
                                 re.IGNORECASE)

    def check(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ExceptHandler):
                yield from self._check_handler(ctx, node)
            elif isinstance(node, ast.If):
                yield from self._check_branch(ctx, node)

    def _check_handler(self, ctx, handler: ast.ExceptHandler):
        if _contains_logging(handler):
            return
        for sub in ast.walk(handler):
            change = self._precision_change(sub)
            if change:
                yield self.finding(
                    ctx, sub,
                    f"except handler {change} without logging a "
                    "GuardEvent or warning — silent precision/backend "
                    "fallbacks hide real failures (the PR 6 fp16→fp32 "
                    "incident)",
                )
                return

    def _check_branch(self, ctx, node: ast.If):
        # conditional `return "fp32"`-style fallback inside an un-loud
        # branch of a function that also returns other precision values
        for body in (node.body, node.orelse):
            for stmt in body:
                if isinstance(stmt, ast.Return) and \
                        isinstance(stmt.value, ast.Constant) and \
                        stmt.value.value in PRECISION_STRINGS:
                    if not _contains_logging(node):
                        yield self.finding(
                            ctx, stmt,
                            "conditional fallback returns "
                            f"{stmt.value.value!r} without a log/"
                            "GuardEvent — degrade loudly (see "
                            "recovery._resolve_precision) or pragma a "
                            "reviewed build-time fallback",
                        )

    def _precision_change(self, node) -> str | None:
        if isinstance(node, ast.Call):
            tail = call_tail(node)
            if tail == "with_records":
                return "changes the record dtype (`.with_records`)"
            if tail == "replace":
                for kw in node.keywords:
                    if kw.arg and self.PRECISION_FIELD.search(kw.arg):
                        return f"replaces `{kw.arg}=` on a config"
        if isinstance(node, ast.Assign):
            for t in node.targets:
                tn = dotted_name(t)
                if tn and self.PRECISION_FIELD.search(tn.rsplit(".", 1)[-1]) \
                        and isinstance(node.value, ast.Constant) and \
                        node.value.value in PRECISION_STRINGS:
                    return f"assigns {node.value.value!r} to `{tn}`"
        return None


# --------------------------------------------------------------------------
def default_rules() -> list[Rule]:
    return [
        DtypeLiteralRule(),
        HostSyncInScanRule(),
        CondUnderVmapRule(),
        StaticArgHashabilityRule(),
        DonationAliasRule(),
        SilentFallbackRule(),
    ]


RULE_NAMES = tuple(r.name for r in default_rules())
