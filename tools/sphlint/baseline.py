"""Committed-baseline handling for sphlint.

The baseline (``sphlint_baseline.json`` at the repo root) is the list
of findings the team has triaged and accepted — typically legacy code
slated for migration rather than new violations. Matching is EXACT and
symmetric:

* a finding not in the baseline fails the run (new violation);
* a baseline entry with no matching finding ALSO fails the run (stale
  baseline — the debt was paid, delete the entry).

``python -m tools.sphlint baseline <paths>`` regenerates the file from
the current findings; review the diff like any other code change.
"""
from __future__ import annotations

import json
from pathlib import Path

from tools.sphlint.engine import Finding

BASELINE_NAME = "sphlint_baseline.json"


def load(path: Path) -> list[Finding]:
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    return [Finding.from_json(d) for d in data.get("findings", [])]


def save(path: Path, findings: list[Finding]) -> None:
    payload = {
        "comment": (
            "Triaged sphlint findings. Regenerate with "
            "`python -m tools.sphlint baseline src/repro benchmarks`; "
            "stale entries fail `sphlint check`."
        ),
        "findings": [f.to_json() for f in findings],
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")


def partition(findings: list[Finding], baseline: list[Finding]):
    """Split into (new, matched, stale) by exact ``Finding.key``.

    Duplicate keys are matched with multiplicity: two identical
    findings need two baseline entries.
    """
    pool: dict[tuple, int] = {}
    for b in baseline:
        pool[b.key] = pool.get(b.key, 0) + 1
    new: list[Finding] = []
    matched: list[Finding] = []
    for f in findings:
        if pool.get(f.key, 0) > 0:
            pool[f.key] -= 1
            matched.append(f)
        else:
            new.append(f)
    stale: list[Finding] = []
    for b in baseline:
        if pool.get(b.key, 0) > 0:
            pool[b.key] -= 1
            stale.append(b)
    return new, matched, stale
