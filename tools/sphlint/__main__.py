"""sphlint CLI: ``python -m tools.sphlint {check,trace,baseline}``.

``check``    Layer A — AST rules, stdlib only, <5s, CI-blocking.
``trace``    Layer B — compile the production programs and audit the
             jaxprs (imports jax; see ``trace.py``).
``baseline`` Regenerate ``sphlint_baseline.json`` from current findings.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

DEFAULT_PATHS = ["src/repro", "benchmarks", "tools"]


def _repo_root() -> Path:
    return Path(__file__).resolve().parents[2]


def _scope_baseline(base, paths):
    """Baseline entries under the linted paths only — checking a subtree
    must not report entries from unlinted siblings as stale."""
    import os

    prefixes = [os.path.normpath(p) for p in paths]
    return [
        f for f in base
        if any(os.path.normpath(f.path) == p
               or os.path.normpath(f.path).startswith(p + os.sep)
               for p in prefixes)
    ]


def cmd_check(args) -> int:
    from tools.sphlint import baseline as bl
    from tools.sphlint.engine import lint_paths, render_findings

    t0 = time.perf_counter()
    paths = args.paths or DEFAULT_PATHS
    findings = lint_paths(paths)
    base_path = Path(args.baseline) if args.baseline else \
        _repo_root() / bl.BASELINE_NAME
    base = bl.load(base_path) if not args.no_baseline else []
    base = _scope_baseline(base, paths)
    new, matched, stale = bl.partition(findings, base)
    dt = time.perf_counter() - t0

    if new:
        print(f"sphlint: {len(new)} unbaselined finding(s):",
              file=sys.stderr)
        render_findings(new, stream=sys.stderr)
    if stale:
        print(f"sphlint: {len(stale)} STALE baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} (finding gone — "
              f"delete from {base_path.name} or rerun "
              "`python -m tools.sphlint baseline`):", file=sys.stderr)
        render_findings(stale, stream=sys.stderr)
    errors = [f for f in new if f.severity == "error"]
    status = 1 if (errors or stale) else 0
    summary = (f"sphlint check: {len(findings)} finding(s) "
               f"({len(matched)} baselined, {len(new)} new, "
               f"{len(stale)} stale) in {dt:.2f}s")
    print(summary, file=sys.stderr if status else sys.stdout)
    if new and not errors and not stale:
        print("sphlint: new findings are warnings only — not failing",
              file=sys.stderr)
    return status


def cmd_baseline(args) -> int:
    from tools.sphlint import baseline as bl
    from tools.sphlint.engine import lint_paths

    findings = lint_paths(args.paths or DEFAULT_PATHS)
    base_path = Path(args.baseline) if args.baseline else \
        _repo_root() / bl.BASELINE_NAME
    bl.save(base_path, findings)
    print(f"sphlint: wrote {len(findings)} finding(s) to {base_path}")
    return 0


def cmd_trace(args) -> int:
    from tools.sphlint.trace import run_trace_audit

    return run_trace_audit(
        backends=[b.strip() for b in args.backends.split(",") if b.strip()],
        cases=[c.strip() for c in args.cases.split(",") if c.strip()],
        n=args.n,
        report_path=Path(args.report) if args.report else None,
        verbose=args.verbose,
    )


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m tools.sphlint",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = p.add_subparsers(dest="cmd", required=True)

    c = sub.add_parser("check", help="run Layer A AST rules")
    c.add_argument("paths", nargs="*", help=f"files/dirs "
                   f"(default: {' '.join(DEFAULT_PATHS)})")
    c.add_argument("--baseline", help="baseline JSON path "
                   "(default: <repo>/sphlint_baseline.json)")
    c.add_argument("--no-baseline", action="store_true",
                   help="report every finding, ignore the baseline")
    c.set_defaults(fn=cmd_check)

    b = sub.add_parser("baseline",
                       help="regenerate the committed baseline")
    b.add_argument("paths", nargs="*")
    b.add_argument("--baseline", help="output path")
    b.set_defaults(fn=cmd_baseline)

    t = sub.add_parser("trace", help="Layer B jaxpr audit (imports jax)")
    t.add_argument("--backends", default="reference,xla,pallas",
                   help="comma-separated force backends")
    t.add_argument("--cases", default="dam_break,taylor_green",
                   help="comma-separated registered cases")
    t.add_argument("--n", type=int, default=300,
                   help="particle budget per case (kept tiny: the audit "
                   "inspects programs, not physics)")
    t.add_argument("--report", help="write the JSON report here")
    t.add_argument("--verbose", action="store_true",
                   help="print per-program dtype census tables")
    t.set_defaults(fn=cmd_trace)
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
