"""sphlint — trace-hygiene & mixed-precision static analysis.

Two layers:

* **Layer A** (``sphlint check``): pure-AST rules, no JAX import, fast
  enough for a pre-commit hook. Every rule is a minimized replay of an
  incident this repo actually paid for (see ``rules.py`` and the README
  rule table).
* **Layer B** (``sphlint trace``): compiles the production step/rebuild
  programs and audits the jaxprs for the invariants the AST cannot see
  (fp16-op confinement, in-scan callbacks, donation, buffer aliasing).

Run as ``python -m tools.sphlint [check|trace|baseline]`` from the repo
root, or via the ``python -m repro.sph lint`` alias.
"""
from tools.sphlint.engine import Finding, lint_paths  # noqa: F401

__version__ = "1.0"
