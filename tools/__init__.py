"""Repo-local developer tooling (not shipped under src/)."""
